"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
dry-run artifacts:  PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import glob
import json
import os

BASE = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_all():
    rows = []
    for path in sorted(glob.glob(os.path.join(BASE, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(rows):
    out = ["| arch | shape | mesh | status | compile s | args GiB/dev | temp GiB/dev |",
           "|---|---|---|---|---|---|---|"]
    for d in rows:
        if "shape" not in d:
            continue   # linksage-gnn auxiliary artifact has its own format
        ma = d.get("memory_analysis", {})
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | {d.get('status')} "
            f"| {d.get('compile_seconds', 0):.1f} "
            f"| {fmt_bytes(ma.get('argument_size', 0))} "
            f"| {fmt_bytes(ma.get('temp_size', 0))} |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | t_compute ms | t_memory ms | t_collective ms | "
           "dominant | useful | coll GiB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if d.get("mesh") != "16x16" or "t_compute_s" not in d or "shape" not in d:
            continue
        out.append(
            f"| {d['arch']} | {d['shape']} "
            f"| {d['t_compute_s'] * 1e3:.1f} | {d['t_memory_s'] * 1e3:.1f} "
            f"| {d['t_collective_s'] * 1e3:.1f} | {d['dominant']} "
            f"| {d['useful_flops_ratio']:.2f} "
            f"| {fmt_bytes(d['coll_bytes_per_dev'])} |")
    return "\n".join(out)


def summarize(rows):
    done = [d for d in rows if d.get("status") == "compiled"]
    failed = [d for d in rows if d.get("status") == "FAILED"]
    print(f"# {len(done)} compiled, {len(failed)} failed, {len(rows)} total\n")
    if failed:
        print("## FAILURES")
        for d in failed:
            print(f"- {d['arch']} × {d['shape']} × {d['mesh']}: {d.get('error')}")
        print()
    print("## §Dry-run\n")
    print(dryrun_table(rows))
    print("\n## §Roofline (single-pod 16x16)\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    summarize(load_all())
