"""Telemetry overhead benchmark (§15 acceptance gate).

The hard contract: telemetry never changes bits, and DISABLED mode — the
production default — costs effectively nothing on the nearline hot path.
Three measurements back that up:

  * ``obs_nearline_disabled``  — events/s through the instrumented nearline
                                 replay with the null tracer installed (the
                                 default); this is the arm regression diffs
                                 track
  * ``obs_null_span_ns``       — ns per disabled span enter/exit, measured
                                 by microbenchmark; multiplied by the
                                 spans-per-event count observed in an
                                 enabled run, it bounds the disabled-mode
                                 overhead fraction — ASSERTED < 2%
  * ``obs_nearline_enabled``   — the same replay with a wall-clock Tracer
                                 recording every span, reporting the
                                 ENABLED cost as a fraction of the disabled
                                 arm (informational, not gated)

Both replay arms consume identical RNG streams; the enabled arm's store is
asserted bit-identical to the disabled arm's (the never-changes-bits gate,
here on the nearline path).
"""
from __future__ import annotations

import time
from dataclasses import replace

import jax
import numpy as np

from benchmarks.common import emit, standard_graph
from repro.configs.linksage import CONFIG as GNN_CONFIG
from repro.core import encoder as enc
from repro.core.embeddings import tables_bitwise_equal
from repro.core.nearline import Event, NearlineInference
from repro.data import marketplace_event_stream
from repro.obs import Histogram, MetricsRegistry, Tracer, set_tracer, span

N_EVENTS = 512
MICRO_BATCH = 64


def _replay(g, cfg, params, events):
    """The nearline_bench harness: bootstrap, one warm-up micro-batch
    (compiles the steady-state jit bucket outside the timed region), then
    the timed replay of ``events``."""
    nl = NearlineInference(cfg, params, micro_batch=MICRO_BATCH, seed=0)
    nl.bootstrap_from_graph(g)
    wrng = np.random.default_rng(99)
    for _ in range(MICRO_BATCH):
        nl.topic.publish(Event(time=0.0, kind="engagement", payload={
            "member_id": int(wrng.integers(0, g.num_nodes["member"])),
            "job_id": int(wrng.integers(0, g.num_nodes["job"]))}))
    nl.process()
    nl.metrics = type(nl.metrics)()
    for ev in events:
        nl.topic.publish(ev)
    t0 = time.perf_counter()
    nl.process()
    dt = time.perf_counter() - t0
    return nl, dt


def bench_obs_overhead():
    g, _ = standard_graph(0)
    cfg = replace(GNN_CONFIG, hidden_dim=64, embed_dim=64, fanouts=(8, 4),
                  feat_dim=g.feat_dim)
    params = enc.encoder_init(jax.random.PRNGKey(0), cfg)
    events = marketplace_event_stream(g, np.random.default_rng(0), N_EVENTS,
                                      attrs=("title", "company", "skill"))

    # disabled arm: the null tracer (the process default) ------------------
    set_tracer(None)
    off, dt_off = _replay(g, cfg, params, events)
    s_off = off.metrics.summary()
    rate_off = s_off["events"] / dt_off
    emit("obs_nearline_disabled", dt_off / max(s_off["batches"], 1) * 1e6,
         f"events_per_s={rate_off:.0f};batches={s_off['batches']}")

    # enabled arm: every span recorded on the wall clock -------------------
    tracer = Tracer(clock="wall")
    set_tracer(tracer)
    try:
        on, dt_on = _replay(g, cfg, params, events)
    finally:
        set_tracer(None)
    s_on = on.metrics.summary()
    rate_on = s_on["events"] / dt_on
    assert tables_bitwise_equal(off.embedding_store.live_embeddings(),
                                on.embedding_store.live_embeddings()), \
        "telemetry changed bits on the nearline path"
    spans_per_event = len(tracer.spans) / max(s_on["events"], 1)
    enabled_cost = rate_off / rate_on - 1.0
    emit("obs_nearline_enabled", dt_on / max(s_on["batches"], 1) * 1e6,
         f"events_per_s={rate_on:.0f};spans={len(tracer.spans)};"
         f"spans_per_event={spans_per_event:.2f};"
         f"enabled_cost_frac={enabled_cost:.4f};bit_parity=ok")

    # null-span microbench + the <2% disabled-overhead gate ----------------
    k = 200_000
    t0 = time.perf_counter()
    for _ in range(k):
        with span("bench"):
            pass
    null_ns = (time.perf_counter() - t0) / k * 1e9
    event_us = 1e6 / rate_off                      # µs of real work per event
    frac = (null_ns * 1e-3 * spans_per_event) / event_us
    assert frac < 0.02, (
        f"disabled-mode overhead {frac:.2%} >= 2% "
        f"({null_ns:.0f}ns/span x {spans_per_event:.2f} spans/event "
        f"vs {event_us:.0f}us/event)")
    emit("obs_disabled_overhead", null_ns * 1e-3,
         f"null_span_ns={null_ns:.0f};spans_per_event={spans_per_event:.2f};"
         f"disabled_overhead_frac={frac:.6f};gate=lt_2pct")


def bench_obs_metric_ops():
    """Registry primitive costs: histogram record (the per-sample hot op),
    quantile extraction, and labeled counter increments through live
    handles (the pattern the cluster's event counters use)."""
    h = Histogram()
    vals = np.random.default_rng(0).lognormal(-6, 2, 4096)
    t0 = time.perf_counter()
    for _ in range(64):
        h.record_many(vals)
    rec_us = (time.perf_counter() - t0) / (64 * len(vals)) * 1e6
    t0 = time.perf_counter()
    for _ in range(1000):
        h.quantile(0.99)
    q_us = (time.perf_counter() - t0) / 1000 * 1e6

    reg = MetricsRegistry()
    c = reg.counter("bench.events", shard="0")       # handle held hot-path
    t0 = time.perf_counter()
    for _ in range(100_000):
        c.inc()
    inc_ns = (time.perf_counter() - t0) / 100_000 * 1e9
    emit("obs_metric_ops", rec_us,
         f"hist_record_us={rec_us:.4f};hist_quantile_p99_us={q_us:.2f};"
         f"counter_inc_ns={inc_ns:.0f};hist_count={h.count}")


ALL_OBS = [
    bench_obs_overhead,
    bench_obs_metric_ops,
]
