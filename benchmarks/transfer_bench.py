"""Embedding-lifecycle + transfer benchmark (§5.2 serving loop, DESIGN.md §9).

Three claims:

  * sweep vs incremental — full-sweep ``publish_version`` throughput
    (nodes/s) vs incremental dirty-closure drain throughput over one event
    stream, plus the recompute amplification (closure nodes per event);
  * parity row — the §9 contract: the incremental drain's live table is
    BIT-IDENTICAL to an offline full sweep at the final graph state (the
    acceptance gate tracks this row);
  * staleness/latency tradeoff — drain cadence (every batch vs end-of-
    window) and an age-out policy, each reporting staleness percentiles vs
    recomputes per event.

Plus the multi-surface train-step rate (all four §7 heads from one
embedding gather).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, standard_graph
from repro.configs.linksage import smoke as gnn_smoke
from repro.core import encoder as enc
from repro.core.embeddings import StalenessPolicy, tables_bitwise_equal
from repro.core.nearline import Event, NearlineInference
from repro.data import marketplace_event_stream

N_EVENTS = 192
MICRO_BATCH = 32


def _cfg(g):
    from dataclasses import replace
    return replace(gnn_smoke(), feat_dim=g.feat_dim)


def _event_stream(g, rng, n=N_EVENTS):
    return marketplace_event_stream(g, rng, n)


def _nearline(g, cfg, params, *, policy, micro_batch=MICRO_BATCH, seed=0):
    nl = NearlineInference(cfg, params, micro_batch=micro_batch, seed=seed,
                           policy=policy)
    nl.bootstrap_from_graph(g)
    return nl


def bench_transfer_sweep_vs_incremental():
    """Offline full-sweep vs incremental dirty-closure recompute — the two
    lifecycle paths over the same event stream, ending bit-identical."""
    g, _ = standard_graph(0)
    cfg = _cfg(g)
    params = enc.encoder_init(jax.random.PRNGKey(0), cfg)
    events = _event_stream(g, np.random.default_rng(0))
    policy = StalenessPolicy(closure_radius=None)   # full K-hop dependency

    # incremental arm: per-micro-batch drain as events arrive; warm the
    # steady-state jit bucket on a throwaway full micro-batch, then reset
    # the counters so the timed region is compile-free
    inc = _nearline(g, cfg, params, policy=policy)
    wrng = np.random.default_rng(99)
    for _ in range(MICRO_BATCH):
        inc.topic.publish(Event(time=0.0, kind="engagement", payload={
            "member_id": int(wrng.integers(0, g.num_nodes["member"])),
            "job_id": int(wrng.integers(0, g.num_nodes["job"]))}))
    inc.process()
    inc.metrics = type(inc.metrics)()
    for ev in events:
        inc.topic.publish(ev)
    t0 = time.perf_counter()
    inc.process()
    dt_inc = time.perf_counter() - t0
    s = inc.metrics.summary()
    emit("transfer_lifecycle_incremental", dt_inc / max(s["batches"], 1) * 1e6,
         f"nodes_per_s={s['nodes_refreshed'] / dt_inc:.0f};"
         f"events_per_s={len(events) / dt_inc:.0f};"
         f"recompute_amplification={s['nodes_refreshed'] / len(events):.2f};"
         f"staleness_p99_s={s['staleness_p99_s']:.1f}")

    # offline arm: ingest the whole window, then one full sweep
    off = _nearline(g, cfg, params, policy=policy)
    for ev in events:
        off.topic.publish(ev)
    off.ingest()
    t0 = time.perf_counter()
    version = off.lifecycle.publish_version(clock=float(len(events)))
    dt_off = time.perf_counter() - t0
    swept = len(off.embedding_store.table(version))
    emit("transfer_lifecycle_sweep", dt_off / max(swept, 1) * 1e6,
         f"nodes_per_s={swept / dt_off:.0f};swept={swept};"
         f"registry={len(off.lifecycle.registry)}")

    # parity row (the acceptance gate): incremental live table ⊇-restricted
    # comparison is NOT enough — key sets must match and bits must match.
    # The incremental arm starts from a published baseline so never-dirty
    # nodes are present in its live table too.
    inc2 = _nearline(g, cfg, params, policy=policy)
    off2 = _nearline(g, cfg, params, policy=policy)
    for nl in (inc2, off2):
        nl.lifecycle.publish_version(clock=0.0)
        for ev in events:
            nl.topic.publish(ev)
    inc2.process()
    off2.ingest()
    v = off2.lifecycle.publish_version(clock=float(len(events)))
    ok = tables_bitwise_equal(inc2.embedding_store.live_embeddings(),
                              off2.embedding_store.table(v))
    emit("transfer_lifecycle_parity", 0.0,
         f"bitwise_identical={int(ok)};"
         f"table_size={len(off2.embedding_store.table(v))}")
    assert ok, "sweep/incremental parity violated"


def bench_transfer_staleness_tradeoff():
    """Recompute cost vs embedding freshness across drain cadences."""
    g, _ = standard_graph(0)
    cfg = _cfg(g)
    params = enc.encoder_init(jax.random.PRNGKey(0), cfg)
    arms = {
        # endpoints only, drained as events arrive (the nearline default)
        "endpoints_nearline": dict(policy=StalenessPolicy(), micro=8),
        # full closure, drained as events arrive (parity-grade freshness)
        "closure_nearline": dict(policy=StalenessPolicy(closure_radius=None),
                                 micro=8),
        # endpoints + 64s age-out: idle nodes refresh on staleness alone
        "endpoints_ageout": dict(policy=StalenessPolicy(max_staleness_s=64.0),
                                 micro=8),
    }
    for label, spec in arms.items():
        nl = _nearline(g, cfg, params, policy=spec["policy"],
                       micro_batch=spec["micro"])
        events = _event_stream(g, np.random.default_rng(1), n=96)
        for ev in events:
            nl.topic.publish(ev)
            nl.process()                    # event-time processing
        s = nl.metrics.summary()
        emit(f"transfer_staleness_{label}", 0.0,
             f"recomputes_per_event={s['nodes_refreshed'] / s['events']:.2f};"
             f"staleness_p50_s={s['staleness_p50_s']:.1f};"
             f"staleness_p99_s={s['staleness_p99_s']:.1f}")


def bench_transfer_multi_surface_step():
    """Steps/s of the jitted 4-surface train step (one shared gather)."""
    from repro.core.transfer import MultiSurfaceTrainer, surface_configs

    rng = np.random.default_rng(0)
    M, J, f, e, B = 512, 128, 32, 32, 256
    tables = {"m_feat": rng.normal(size=(M, f)).astype(np.float32),
              "j_feat": rng.normal(size=(J, f)).astype(np.float32),
              "m_gnn": rng.normal(size=(M, e)).astype(np.float32),
              "j_gnn": rng.normal(size=(J, e)).astype(np.float32),
              "q_feat": rng.normal(size=(M, f)).astype(np.float32)}
    pairs = (rng.integers(0, M, 4 * B), rng.integers(0, J, 4 * B))
    labels = {n: rng.integers(0, 2, 4 * B).astype(np.float32)
              for n in ("taj", "jymbii", "jobsearch", "ebr")}
    mst = MultiSurfaceTrainer(surface_configs(
        other_feat_dim=f, gnn_embed_dim=e, hidden=64, query_dim=f), seed=0)
    mst.fit(tables, pairs, labels, epochs=1, batch_size=B)   # compile
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        mst.fit(tables, pairs, labels, epochs=1, batch_size=B)
    dt = time.perf_counter() - t0
    steps = reps * (4 * B // B)
    emit("transfer_multi_surface_step", dt / steps * 1e6,
         f"steps_per_s={steps / dt:.0f};surfaces=4;batch={B}")


def bench_transfer_retrieval_surface():
    """The §14 retrieval tier on REAL surface vectors (not the synthetic
    clustered corpus retrieval_bench sweeps): build the int8+IVF index
    over trained GNN job embeddings via ``EBRSurface.build_index``, assert
    the exact config returns ids bit-identical to the fp32 oracle, and
    report the production arm's engagement recall vs the oracle's."""
    from benchmarks.common import timed, trained_gnn
    from repro.core.eval import positives_from_edges, recall_from_retrieved
    from repro.core.retrieval import brute_force_topk
    from repro.core.transfer import SURFACES

    g, truth, cfg, tr, m_emb, j_emb = trained_gnn(0, steps=60)
    src, dst = truth["engagements"]
    positives = positives_from_edges(src, dst, m_emb.shape[0])
    members = np.array([i for i, p in enumerate(positives) if p])
    q, pos_sub = m_emb[members], [positives[i] for i in members]

    index = SURFACES["ebr"].build_index(j_emb, quantize="per_row",
                                        num_lists=0, seed=0)
    oracle_ids, _ = brute_force_topk(q, j_emb, 10)
    exact_ids, _ = index.search(q, 10, quantized=False)
    ok = np.array_equal(exact_ids, oracle_ids)
    nprobe = max(1, index.num_lists // 3)
    (ann_ids, _), us = timed(
        lambda: index.search(q, 10, nprobe=nprobe, refine=4))
    emit("transfer_retrieval_ebr", us / len(q),
         f"qps={len(q) / (us / 1e6):.0f};"
         f"recall_at_10={recall_from_retrieved(ann_ids, pos_sub, 10):.4f};"
         f"oracle_recall={recall_from_retrieved(oracle_ids, pos_sub, 10):.4f};"
         f"bitwise_oracle={int(ok)};corpus={len(j_emb)};nprobe={nprobe}")
    assert ok, "exact-search ids differ from fp32 oracle"


ALL_TRANSFER = [
    bench_transfer_sweep_vs_incremental,
    bench_transfer_staleness_tradeoff,
    bench_transfer_multi_surface_step,
    bench_transfer_retrieval_surface,
]
