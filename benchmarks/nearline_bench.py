"""Nearline serving-path benchmark (§5.2, Figure 4).

Replays one synthetic event stream through the nearline pipeline twice:

  * ``batched_jit``     — the optimized hot path: batched sequential join
                          (ring-buffer neighbor stores, deduped multi_gets)
                          + the shape-bucketed jitted encoder;
  * ``scalar_unjitted`` — the pre-optimization baseline: O(B·F1·F2) per-key
                          scalar join + unjitted per-batch encoder dispatch.

Both runs consume identical RNG streams, so they refresh the same
embeddings; only the plumbing differs.  Emits events/s, join ms/batch and
encoder ms/batch per arm plus the speedup row the acceptance gate tracks.
"""
from __future__ import annotations

import time
from dataclasses import replace

import jax
import numpy as np

from benchmarks.common import emit, standard_graph
from repro.configs.linksage import CONFIG as GNN_CONFIG
from repro.core import encoder as enc
from repro.core.nearline import Event, NearlineInference
from repro.data import marketplace_event_stream

N_EVENTS = 512
MICRO_BATCH = 64


def _event_stream(g, rng):
    """Engagements + fresh job postings, the two §5.2 trigger kinds."""
    return marketplace_event_stream(g, rng, N_EVENTS,
                                    attrs=("title", "company", "skill"))


def _replay(g, cfg, params, events, *, join_impl, jit_encoder):
    nl = NearlineInference(cfg, params, micro_batch=MICRO_BATCH, seed=0,
                           join_impl=join_impl, jit_encoder=jit_encoder)
    nl.bootstrap_from_graph(g)
    # identical warmup in BOTH arms (keeps rng/store state equal, so the
    # timed replays sample the same neighborhoods): one full-size
    # micro-batch, which also compiles the jitted arm's steady-state bucket
    # outside the timed region
    wrng = np.random.default_rng(99)
    for _ in range(MICRO_BATCH):
        nl.topic.publish(Event(time=0.0, kind="engagement", payload={
            "member_id": int(wrng.integers(0, g.num_nodes["member"])),
            "job_id": int(wrng.integers(0, g.num_nodes["job"]))}))
    nl.process()
    nl.metrics = type(nl.metrics)()
    for ev in events:
        nl.topic.publish(ev)
    t0 = time.perf_counter()
    nl.process()
    dt = time.perf_counter() - t0
    return nl, dt


def bench_nearline_serving():
    g, truth = standard_graph(0)
    cfg = replace(GNN_CONFIG, hidden_dim=64, embed_dim=64, fanouts=(8, 4),
                  feat_dim=g.feat_dim)
    params = enc.encoder_init(jax.random.PRNGKey(0), cfg)
    events = _event_stream(g, np.random.default_rng(0))

    rates = {}
    for label, join_impl, jit_encoder in (
            ("batched_jit", "batched", True),
            ("scalar_unjitted", "scalar", False)):
        nl, dt = _replay(g, cfg, params, events, join_impl=join_impl,
                         jit_encoder=jit_encoder)
        s = nl.metrics.summary()
        rates[label] = s["events"] / dt
        emit(f"nearline_replay_{label}", dt / max(s["batches"], 1) * 1e6,
             f"events_per_s={rates[label]:.0f};"
             f"join_ms_per_batch={s['join_ms_per_batch']:.2f};"
             f"encoder_ms_per_batch={s['encoder_ms_per_batch']:.2f};"
             f"join_reads={s['join_reads']};batches={s['batches']}")
    emit("nearline_speedup", 0.0,
         f"events_per_s_ratio={rates['batched_jit'] / rates['scalar_unjitted']:.1f}x;"
         f"batched={rates['batched_jit']:.0f};scalar={rates['scalar_unjitted']:.0f}")


def bench_nearline_bucket_stability():
    """Encoder ms/batch must stay flat across consecutive same-bucket batches
    (one trace total — no per-batch retrace)."""
    g, truth = standard_graph(0)
    cfg = replace(GNN_CONFIG, hidden_dim=64, embed_dim=64, fanouts=(8, 4),
                  feat_dim=g.feat_dim)
    params = enc.encoder_init(jax.random.PRNGKey(0), cfg)
    nl = NearlineInference(cfg, params, micro_batch=16, seed=0)
    nl.bootstrap_from_graph(g)
    rng = np.random.default_rng(1)
    per_batch_ms = []
    for i in range(8):
        # 12-16 touched nodes per batch: same 16-bucket, varying node count
        for k in range(6 + (i % 3)):
            nl.topic.publish(Event(time=float(i), kind="engagement", payload={
                "member_id": int(rng.integers(0, g.num_nodes["member"])),
                "job_id": int(rng.integers(0, g.num_nodes["job"]))}))
        before = nl.metrics.encoder_seconds
        nl.process()
        per_batch_ms.append(1e3 * (nl.metrics.encoder_seconds - before))
    steady = per_batch_ms[1:]
    emit("nearline_encoder_bucket_stability", np.mean(steady) * 1e3,
         f"traces={nl.metrics.encoder_traces};"
         f"first_batch_ms={per_batch_ms[0]:.1f};"
         f"steady_ms_mean={np.mean(steady):.2f};"
         f"steady_ms_max={np.max(steady):.2f}")


ALL_NEARLINE = [
    bench_nearline_serving,
    bench_nearline_bucket_stability,
]
