"""Nearline serving-path benchmark (§5.2, Figure 4).

Replays one synthetic event stream through the nearline pipeline twice:

  * ``batched_jit``     — the optimized hot path: batched sequential join
                          (ring-buffer neighbor stores, deduped multi_gets)
                          + the shape-bucketed jitted encoder;
  * ``scalar_unjitted`` — the pre-optimization baseline: O(B·F1·F2) per-key
                          scalar join + unjitted per-batch encoder dispatch.

Both runs consume identical RNG streams, so they refresh the same
embeddings; only the plumbing differs.  Emits events/s, join ms/batch and
encoder ms/batch per arm plus the speedup row the acceptance gate tracks.
"""
from __future__ import annotations

import time
from dataclasses import replace

import jax
import numpy as np

from benchmarks.common import emit, standard_graph
from repro.configs.linksage import CONFIG as GNN_CONFIG
from repro.core import encoder as enc
from repro.core.nearline import Event, NearlineInference
from repro.data import marketplace_event_stream

N_EVENTS = 512
MICRO_BATCH = 64


def _event_stream(g, rng):
    """Engagements + fresh job postings, the two §5.2 trigger kinds."""
    return marketplace_event_stream(g, rng, N_EVENTS,
                                    attrs=("title", "company", "skill"))


def _replay(g, cfg, params, events, *, join_impl, jit_encoder):
    nl = NearlineInference(cfg, params, micro_batch=MICRO_BATCH, seed=0,
                           join_impl=join_impl, jit_encoder=jit_encoder)
    nl.bootstrap_from_graph(g)
    # identical warmup in BOTH arms (keeps rng/store state equal, so the
    # timed replays sample the same neighborhoods): one full-size
    # micro-batch, which also compiles the jitted arm's steady-state bucket
    # outside the timed region
    wrng = np.random.default_rng(99)
    for _ in range(MICRO_BATCH):
        nl.topic.publish(Event(time=0.0, kind="engagement", payload={
            "member_id": int(wrng.integers(0, g.num_nodes["member"])),
            "job_id": int(wrng.integers(0, g.num_nodes["job"]))}))
    nl.process()
    nl.metrics = type(nl.metrics)()
    for ev in events:
        nl.topic.publish(ev)
    t0 = time.perf_counter()
    nl.process()
    dt = time.perf_counter() - t0
    return nl, dt


def bench_nearline_serving():
    g, truth = standard_graph(0)
    cfg = replace(GNN_CONFIG, hidden_dim=64, embed_dim=64, fanouts=(8, 4),
                  feat_dim=g.feat_dim)
    params = enc.encoder_init(jax.random.PRNGKey(0), cfg)
    events = _event_stream(g, np.random.default_rng(0))

    rates = {}
    for label, join_impl, jit_encoder in (
            ("batched_jit", "batched", True),
            ("scalar_unjitted", "scalar", False)):
        nl, dt = _replay(g, cfg, params, events, join_impl=join_impl,
                         jit_encoder=jit_encoder)
        s = nl.metrics.summary()
        rates[label] = s["events"] / dt
        emit(f"nearline_replay_{label}", dt / max(s["batches"], 1) * 1e6,
             f"events_per_s={rates[label]:.0f};"
             f"join_ms_per_batch={s['join_ms_per_batch']:.2f};"
             f"encoder_ms_per_batch={s['encoder_ms_per_batch']:.2f};"
             f"join_reads={s['join_reads']};batches={s['batches']}")
    emit("nearline_speedup", 0.0,
         f"events_per_s_ratio={rates['batched_jit'] / rates['scalar_unjitted']:.1f}x;"
         f"batched={rates['batched_jit']:.0f};scalar={rates['scalar_unjitted']:.0f}")


def bench_nearline_bucket_stability():
    """Encoder ms/batch must stay flat across consecutive same-bucket batches
    (one trace total — no per-batch retrace)."""
    g, truth = standard_graph(0)
    cfg = replace(GNN_CONFIG, hidden_dim=64, embed_dim=64, fanouts=(8, 4),
                  feat_dim=g.feat_dim)
    params = enc.encoder_init(jax.random.PRNGKey(0), cfg)
    nl = NearlineInference(cfg, params, micro_batch=16, seed=0)
    nl.bootstrap_from_graph(g)
    rng = np.random.default_rng(1)
    per_batch_ms = []
    for i in range(8):
        # 12-16 touched nodes per batch: same 16-bucket, varying node count
        for k in range(6 + (i % 3)):
            nl.topic.publish(Event(time=float(i), kind="engagement", payload={
                "member_id": int(rng.integers(0, g.num_nodes["member"])),
                "job_id": int(rng.integers(0, g.num_nodes["job"]))}))
        before = nl.metrics.encoder_seconds
        nl.process()
        per_batch_ms.append(1e3 * (nl.metrics.encoder_seconds - before))
    steady = per_batch_ms[1:]
    emit("nearline_encoder_bucket_stability", np.mean(steady) * 1e3,
         f"traces={nl.metrics.encoder_traces};"
         f"first_batch_ms={per_batch_ms[0]:.1f};"
         f"steady_ms_mean={np.mean(steady):.2f};"
         f"steady_ms_max={np.max(steady):.2f}")


def _warm_encoder_buckets(nl, cfg, up_to: int) -> None:
    """Pre-compile every power-of-two encoder bucket ≤ ``up_to`` OUTSIDE the
    timed region, by feeding zero tiles straight to the lifecycle's jitted
    encoder (bypassing the engine, so no cache state is touched).  Skewed
    replays touch varying dirty-set sizes, and without this the first batch
    to land in a new bucket pays its trace inside the measurement."""
    from repro.core.engine import ComputeGraphBatch
    from repro.core.linksage import _to_jnp

    d, b = cfg.feat_dim, 8
    while b <= up_to:
        shape = (b,)
        feats = [np.zeros((b, d), np.float32)]
        types = [np.zeros((b,), np.int32)]
        masks = []
        for f in cfg.fanouts:
            shape = shape + (f,)
            feats.append(np.zeros(shape + (d,), np.float32))
            types.append(np.zeros(shape, np.int32))
            masks.append(np.zeros(shape, np.float32))
        tile = ComputeGraphBatch(tuple(feats), tuple(types), tuple(masks))
        nl.lifecycle._encode(nl.lifecycle.params, _to_jnp(tile))
        b *= 2


def bench_nearline_cache_sweep():
    """The §11 memory-hierarchy arm: replay ONE power-law (zipf) event
    stream — the skewed access pattern that makes a hot set worth pinning —
    through the nearline pipeline at swept feature-cache hit rates.

    Workload: the production regime the cache exists for — fat features
    (LiGNN-class 256-dim rows) read from a feature store charged with the
    :class:`~repro.core.stores.StoreLatency` remote-NoSQL cost model (per-RPC
    dispatch + per-key media/deserialization; the dict's free reads are the
    unrealistic arm).  Both arms replay against the SAME modeled store; the
    cache intercepts the read path, which is exactly its production job.

    The sweep pins hit rate by prewarming a fraction of the snapshot nodes
    with admission frozen (``admit_after=inf``): 0% is the cold arm (hit
    rate exactly 0), 100% the hot arm (hits on everything but fresh-job
    rows).  A ``learned`` arm runs the real traffic-learned admission and
    reports the cold → steady-state convergence per quarter of the replay.
    Bit-parity with the uncached replay is ASSERTED at hit-rate 0 and at
    hit-rate 1 (the acceptance gate), and the speedup row tracks hot vs
    uncached events/s.
    """
    from repro.core.cache import CacheConfig
    from repro.core.embeddings import tables_bitwise_equal
    from repro.core.graph import NODE_TYPE_ID, NODE_TYPES
    from repro.core.stores import StoreLatency
    from repro.data import GraphGenConfig, generate_job_marketplace_graph

    g, _ = generate_job_marketplace_graph(GraphGenConfig(
        num_members=2000, num_jobs=600, feat_dim=256, seed=0))
    cfg = replace(GNN_CONFIG, hidden_dim=32, embed_dim=32, fanouts=(8, 4),
                  feat_dim=g.feat_dim)
    params = enc.encoder_init(jax.random.PRNGKey(0), cfg)
    events = marketplace_event_stream(g, np.random.default_rng(3), N_EVENTS,
                                      attrs=("title", "company", "skill"),
                                      zipf=1.1)

    def arm(feature_cache=None, prewarm_frac=None, quarters=False):
        nl = NearlineInference(cfg, params, micro_batch=MICRO_BATCH, seed=0,
                               feature_cache=feature_cache)
        nl.bootstrap_from_graph(g)
        if prewarm_frac:
            rng = np.random.default_rng(7)
            for tname in NODE_TYPES:
                n = g.num_nodes.get(tname, 0)
                k = int(round(prewarm_frac * n))
                if k:
                    ids = rng.permutation(n)[:k]
                    nl.engine.prewarm(np.full(k, NODE_TYPE_ID[tname]), ids)
        # bootstrap + prewarm read the store for free; the replay pays the
        # modeled remote-store read cost in EVERY arm
        nl.engine.feature_store.latency = StoreLatency()
        _warm_encoder_buckets(nl, cfg, MICRO_BATCH)
        wrng = np.random.default_rng(99)
        for _ in range(MICRO_BATCH):      # compile outside the timed region
            nl.topic.publish(Event(time=0.0, kind="engagement", payload={
                "member_id": int(wrng.integers(0, g.num_nodes["member"])),
                "job_id": int(wrng.integers(0, g.num_nodes["job"]))}))
        nl.process()
        nl.metrics = type(nl.metrics)()
        for ev in events:
            nl.topic.publish(ev)
        hit_curve = []
        t0 = time.perf_counter()
        if quarters:
            for _ in range(4):
                h0, m0 = (nl.metrics.feature_cache_hits,
                          nl.metrics.feature_cache_misses)
                nl.process(max_batches=(N_EVENTS // MICRO_BATCH) // 4)
                dh = nl.metrics.feature_cache_hits - h0
                dm = nl.metrics.feature_cache_misses - m0
                hit_curve.append(dh / max(dh + dm, 1))
        nl.process()
        dt = time.perf_counter() - t0
        s = nl.metrics.summary()
        return nl, dt, s, hit_curve

    base, base_dt, base_s, _ = arm()
    base_live = base.embedding_store.live_embeddings()
    base_rate = base_s["events"] / base_dt
    emit("nearline_cache_uncached", base_dt / max(base_s["batches"], 1) * 1e6,
         f"events_per_s={base_rate:.0f};"
         f"join_ms_per_batch={base_s['join_ms_per_batch']:.2f}")

    rates = {}
    for frac in (0.0, 0.25, 0.5, 0.75, 1.0):
        nl, dt, s, _ = arm(
            feature_cache=CacheConfig(slots=8192, admit_after=float("inf")),
            prewarm_frac=frac)
        rate = s["events"] / dt
        rates[frac] = rate
        parity = ""
        if frac in (0.0, 1.0):           # the acceptance-gate parity rows
            assert tables_bitwise_equal(
                base_live, nl.embedding_store.live_embeddings()), frac
            parity = ";bit_parity=ok"
        if frac == 0.0:
            assert s["feature_cache_hits"] == 0     # hit rate exactly 0
        emit(f"nearline_cache_prewarm_{int(frac * 100)}",
             dt / max(s["batches"], 1) * 1e6,
             f"events_per_s={rate:.0f};"
             f"hit_rate={s['feature_cache_hit_rate']:.3f};"
             f"join_ms_per_batch={s['join_ms_per_batch']:.2f}" + parity)

    _, dt, s, curve = arm(feature_cache=8192, quarters=True)
    emit("nearline_cache_learned", dt / max(s["batches"], 1) * 1e6,
         f"events_per_s={s['events'] / dt:.0f};"
         f"hit_rate={s['feature_cache_hit_rate']:.3f};"
         f"hit_rate_by_quarter={'/'.join(f'{h:.2f}' for h in curve)}")

    emit("nearline_cache_speedup", 0.0,
         f"events_per_s_ratio={rates[1.0] / base_rate:.2f}x;"
         f"hot={rates[1.0]:.0f};uncached={base_rate:.0f};"
         f"cold={rates[0.0]:.0f}")


ALL_NEARLINE = [
    bench_nearline_serving,
    bench_nearline_bucket_stability,
    bench_nearline_cache_sweep,
]
