"""Memory-hierarchy micro-benchmarks (DESIGN.md §11).

What the tier-1 feature cache costs and buys, measured off the serving
pipeline so each term is visible in isolation:

  * slab ops        — raw SlabCache lookup+gather and insert-under-eviction
                      throughput (the overhead a hit/miss adds to a gather);
  * hit-rate sweep  — CachedEngine.gather_features against a feature store
                      charged with the StoreLatency remote-NoSQL cost model,
                      at pinned hit rates 0 → 1 (frozen admission + partial
                      prewarm): the events/s-vs-hit-rate curve the nearline
                      sweep sees, without the encoder around it;
  * eviction churn  — a working set ~4x the slab, so every gather admits and
                      evicts; the parity row asserts cached output stays
                      bit-identical to the uncached engine THROUGH the churn;
  * sampling arms   — passthrough vs cache_aware sample_batched cost, plus
                      the resident fraction of picks each strategy yields on
                      a half-warm cache (the quantity cache_aware exists to
                      raise).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, standard_graph, timed
from repro.core.cache import CacheConfig, CachedEngine, SlabCache
from repro.core.engine import StreamingEngine
from repro.core.graph import NODE_TYPE_ID, NODE_TYPES
from repro.core.stores import StoreLatency

FEAT_DIM = 256
SLOTS = 4096


def _streaming(g, latency=None):
    eng = StreamingEngine(g.feat_dim, max_neighbors=128)
    eng.bootstrap_from_graph(g)
    eng.feature_store.latency = latency
    return eng


def _all_keys(g):
    tids = np.concatenate([np.full(g.num_nodes.get(t, 0), NODE_TYPE_ID[t],
                                   np.int64) for t in NODE_TYPES])
    nids = np.concatenate([np.arange(g.num_nodes.get(t, 0), dtype=np.int64)
                           for t in NODE_TYPES])
    return tids, nids


def bench_cache_slab_ops():
    rng = np.random.default_rng(0)
    cache = SlabCache(FEAT_DIM, slots=SLOTS, admit_after=0)
    tids = np.zeros(SLOTS, np.int64)
    nids = np.arange(SLOTS, dtype=np.int64)
    cache.insert(tids, nids, rng.random((SLOTS, FEAT_DIM)).astype(np.float32))

    q = rng.integers(0, SLOTS, 2048).astype(np.int64)

    def hit_path():
        slots = cache.lookup(np.zeros(2048, np.int64), q)
        rows = cache.gather(slots)
        cache.touch(slots)
        return rows

    _, us = timed(hit_path, repeats=5)
    emit("cache_slab_lookup_gather", us,
         f"keys_per_s={2048 / (us / 1e6):.0f};slots={SLOTS};dim={FEAT_DIM}")

    for policy in ("clock", "lfu"):
        churn = SlabCache(FEAT_DIM, slots=1024, admit_after=0, policy=policy,
                          device=False)
        rows = rng.random((256, FEAT_DIM)).astype(np.float32)
        state = {"next": 0}

        def insert_fresh():
            nids = np.arange(state["next"], state["next"] + 256,
                             dtype=np.int64)
            state["next"] += 256
            churn.insert(np.zeros(256, np.int64), nids, rows)

        _, us = timed(insert_fresh, repeats=5)
        emit(f"cache_slab_insert_churn_{policy}", us,
             f"inserts_per_s={256 / (us / 1e6):.0f};"
             f"evictions={churn.evictions};slots=1024")


def bench_cache_gather_hit_sweep():
    """µs per 4096-key gather vs pinned hit rate, modeled remote store."""
    g, _ = standard_graph(0)
    eng = _streaming(g, latency=StoreLatency())
    tids, nids = _all_keys(g)
    rng = np.random.default_rng(1)
    sel = rng.integers(0, len(tids), 4096)
    qt, qi = tids[sel], nids[sel]
    oracle = eng.gather_features(qt, qi)

    base = None
    for frac in (0.0, 0.5, 1.0):
        ce = CachedEngine(_streaming(g, latency=StoreLatency()),
                          SlabCache(g.feat_dim, CacheConfig(
                              slots=8192, admit_after=float("inf"))))
        k = int(round(frac * len(tids)))
        if k:
            warm = rng.permutation(len(tids))[:k]
            ce.prewarm(tids[warm], nids[warm])
        out, us = timed(lambda: ce.gather_features(qt, qi), repeats=5)
        assert (out.tobytes() == oracle.tobytes()), frac   # parity every arm
        base = base or us
        emit(f"cache_gather_prewarm_{int(frac * 100)}", us,
             f"keys_per_s={4096 / (us / 1e6):.0f};"
             f"hit_rate={ce.cache.hit_rate():.3f};"
             f"speedup_vs_cold={base / us:.2f}x;bit_parity=ok")


def bench_cache_eviction_churn_parity():
    """Working set ~4x the slab: every gather admits + evicts, and the
    output must STAY bit-identical to the uncached engine through it."""
    g, _ = standard_graph(0)
    eng = _streaming(g)
    ce = CachedEngine(_streaming(g), SlabCache(g.feat_dim, slots=192,
                                               admit_after=0))
    tids, nids = _all_keys(g)
    rng = np.random.default_rng(2)

    def churn():
        for _ in range(8):
            sel = rng.integers(0, len(tids), 512)
            got = ce.gather_features(tids[sel], nids[sel])
            want = eng.gather_features(tids[sel], nids[sel])
            assert got.tobytes() == want.tobytes()
        return ce

    _, us = timed(churn, repeats=3)
    emit("cache_eviction_churn", us,
         f"evictions={ce.cache.evictions};"
         f"hit_rate={ce.cache.hit_rate():.3f};slots=192;bit_parity=ok")


def bench_cache_aware_sampling():
    """passthrough vs cache_aware pick cost + resident-pick fraction on a
    half-warm cache (the fraction of sampled neighbors whose features are
    already slab-resident — the gather work the strategy avoids)."""
    g, _ = standard_graph(0)
    tids, nids = _all_keys(g)
    rng = np.random.default_rng(3)
    warm = rng.permutation(len(tids))[:len(tids) // 2]

    ids = (np.arange(256) % g.num_nodes["member"]).astype(np.int64)
    types = np.full(256, NODE_TYPE_ID["member"], np.int64)
    u = rng.random((256, 8))

    for sampling in ("passthrough", "cache_aware"):
        ce = CachedEngine(_streaming(g), SlabCache(g.feat_dim, CacheConfig(
            slots=8192, admit_after=float("inf"))), sampling=sampling)
        ce.prewarm(tids[warm], nids[warm])
        (ty, nid, mask), us = timed(
            lambda: ce.sample_batched(types, ids, 8, u), repeats=5)
        picked = mask.reshape(-1) > 0
        resident = ce.cache.lookup(
            ty.reshape(-1)[picked].astype(np.int64),
            nid.reshape(-1)[picked].astype(np.int64)) >= 0
        emit(f"cache_sampling_{sampling}", us,
             f"parents_per_s={256 / (us / 1e6):.0f};"
             f"resident_pick_frac={resident.mean():.3f}")


ALL_CACHE = [
    bench_cache_slab_ops,
    bench_cache_gather_hit_sweep,
    bench_cache_eviction_churn_parity,
    bench_cache_aware_sampling,
]
